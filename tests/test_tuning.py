"""Measured-performance layer: harness, tuning cache, and the one
invariant everything rests on — a tuned tile changes TIME, never BITS.

Covers the ISSUE-10 acceptance surface: cache hit / miss /
version-mismatch fallback to defaults, deterministic winner selection
under an injected fake timer, roofline pruning that can never discard
the default candidate, and tuned-vs-default bit-identity through the
public dispatch of all four kernel families in interpret mode
(bitserial plain / grouped, kv_attention, jl_plan), including the
pad-path fix for untileable N under a tuned non-default tile.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import quantize_linear, quantize_stacked
from repro.kernels import tuning
from repro.kernels.bitserial.ops import (bitserial_matmul,
                                         bitserial_matmul_grouped,
                                         pad_tile_n, resolve_tile_n)
from repro.kernels.jl_estimator.ops import plan_bits, resolve_u_tile
from repro.kernels.kv_attention.ops import resolve_tile_t
from repro.kernels.kv_attention.ops import kv_decode_attention
from repro.kernels.tuning import TuningCache, measure, shape_bucket


@pytest.fixture(autouse=True)
def _pristine_cache(monkeypatch):
    """Every test starts and ends with NO active cache and no env var —
    the module's process-global state must never leak across tests."""
    monkeypatch.delenv(tuning.ENV_CACHE_VAR, raising=False)
    tuning._ACTIVE, tuning._ENV_LOADED_FROM = None, None
    yield
    tuning._ACTIVE, tuning._ENV_LOADED_FROM = None, None


def _install(kernel, n, bits, tile):
    cache = TuningCache()
    cache.put(tuning.platform_name(), kernel, n, bits, tile)
    tuning.use_cache(cache)
    return cache


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------
def test_measure_median_with_injected_clock():
    """warmup calls are untimed; the median is over reps only."""
    ticks = iter([0.0, 5.0,            # rep 1 -> 5s
                  10.0, 11.0,          # rep 2 -> 1s
                  20.0, 23.0])         # rep 3 -> 3s
    calls = []
    r = measure(lambda: calls.append(1), warmup=2, reps=3,
                clock=lambda: next(ticks))
    assert len(calls) == 5             # 2 warmup + 3 timed
    assert r.samples == (5.0, 1.0, 3.0)
    assert r.seconds == 3.0            # median, not mean (= 3.0 either way)


def test_measure_even_reps_and_out():
    ticks = iter([0.0, 4.0, 0.0, 2.0])
    r = measure(lambda: jnp.ones((2,)), warmup=0, reps=2,
                clock=lambda: next(ticks))
    assert r.seconds == 3.0            # mean of the middle pair
    np.testing.assert_array_equal(np.asarray(r.out), [1.0, 1.0])
    with pytest.raises(ValueError):
        measure(lambda: None, reps=0)


# ---------------------------------------------------------------------------
# Cache contract
# ---------------------------------------------------------------------------
def test_shape_bucket_pow2():
    assert [shape_bucket(n) for n in (1, 2, 3, 128, 200, 256)] == \
        [1, 2, 4, 128, 256, 256]


def test_cache_roundtrip_and_miss(tmp_path):
    cache = TuningCache()
    key = cache.put("cpu", "bitserial", 200, 4, 64)
    assert key == "cpu/bitserial/n256/b4"
    p = tmp_path / "tc.json"
    cache.save(str(p))
    loaded = TuningCache.load(str(p))
    # n=256 buckets with n=200: one entry serves the family
    assert loaded.lookup("cpu", "bitserial", 256, 4) == 64
    assert loaded.lookup("cpu", "bitserial", 512, 4) is None   # miss
    assert loaded.lookup("tpu", "bitserial", 256, 4) is None   # platform
    assert loaded.lookup("cpu", "kv_attention", 256, 4) is None


def test_version_mismatch_and_corrupt_load_empty(tmp_path):
    """ANY load problem yields an empty cache -> every lookup misses ->
    dispatch uses the hardcoded defaults. Never garbage, never a raise."""
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"version": tuning.CACHE_VERSION + 1,
                                 "entries": {"cpu/bitserial/n256/b4": 64}}))
    assert TuningCache.load(str(stale)).entries == {}
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{not json")
    assert TuningCache.load(str(corrupt)).entries == {}
    assert TuningCache.load(str(tmp_path / "absent.json")).entries == {}
    badtype = tmp_path / "badtype.json"
    badtype.write_text(json.dumps({"version": tuning.CACHE_VERSION,
                                   "entries": {"k": "not-an-int"}}))
    assert TuningCache.load(str(badtype)).entries == {}


def test_env_var_install_and_explicit_override(tmp_path, monkeypatch):
    p = tmp_path / "tc.json"
    cache = TuningCache()
    cache.put(tuning.platform_name(), "bitserial", 256, 4, 64)
    cache.save(str(p))
    assert tuning.tuned_tile("bitserial", n=256, bits=4) is None
    monkeypatch.setenv(tuning.ENV_CACHE_VAR, str(p))
    assert tuning.tuned_tile("bitserial", n=256, bits=4) == 64
    # explicit install wins over the env var...
    tuning.use_cache(None)
    assert tuning.tuned_tile("bitserial", n=256, bits=4) is None
    # ...and env removal clears a previously env-loaded cache
    tuning._ACTIVE, tuning._ENV_LOADED_FROM = None, None
    assert tuning.tuned_tile("bitserial", n=256, bits=4) == 64
    monkeypatch.delenv(tuning.ENV_CACHE_VAR)
    assert tuning.tuned_tile("bitserial", n=256, bits=4) is None


def test_resolvers_fall_back_to_defaults_on_miss():
    """With no cache installed, every resolver reproduces the historical
    defaults — the no-cache == pre-tuning-layer contract."""
    assert resolve_tile_n(256, 4) == 256
    assert resolve_tile_n(384, 4) == 128
    assert resolve_tile_n(200, 4) == 0          # caller pads
    assert pad_tile_n(200, 4) == 128
    assert resolve_tile_t(128, 4) == (128, 0)
    assert resolve_u_tile(8) == 1


def test_resolvers_consume_and_validate_tuned_tiles():
    _install("bitserial", 256, 4, 64)
    assert resolve_tile_n(256, 4) == 64
    assert resolve_tile_n(256, 6) == 256        # different bits: miss
    assert pad_tile_n(200, 4) == 64             # same n256 bucket
    _install("bitserial", 256, 4, 48)           # does NOT divide 256
    assert resolve_tile_n(256, 4) == 256        # ignored -> default
    _install("kv_attention", 128, 4, 32)
    assert resolve_tile_t(128, 4) == (32, 0)
    assert resolve_tile_t(100, 4) == (32, 28)   # n128 bucket; pad_t up
    _install("jl_plan", 6, 0, 2)
    assert resolve_u_tile(6) == 2
    assert resolve_u_tile(5) == 1               # tuned 2 doesn't divide


# ---------------------------------------------------------------------------
# Winner selection (benchmarks/autotune.py)
# ---------------------------------------------------------------------------
def _fake_timer(times):
    """Deterministic timer: seconds per candidate, keyed by the tile the
    runner was built for (runners here are `lambda: tile`)."""
    return lambda runner: times[runner()]


def test_pick_winner_deterministic_with_fake_timer():
    from benchmarks.autotune import pick_winner
    times = {256: 3.0, 128: 1.0, 64: 2.0}
    args = ([256, 128, 64], lambda c: 0.0, lambda c: (lambda: c),
            _fake_timer(times))
    w1, measured1, pruned1 = pick_winner(*args)
    w2, measured2, pruned2 = pick_winner(*args)
    assert (w1, measured1, pruned1) == (w2, measured2, pruned2) == \
        (128, times, [])
    # strict minimum: a tie keeps the default
    tie = _fake_timer({256: 1.0, 128: 1.0, 64: 1.0})
    assert pick_winner([256, 128, 64], lambda c: 0.0,
                       lambda c: (lambda: c), tie)[0] == 256


def test_pruning_never_discards_default():
    """The default candidate is measured first UNCONDITIONALLY, even
    when its modeled floor is the worst — the cache-miss fallback must
    always have a measurement. Non-defaults whose modeled floor exceeds
    the best measured time are skipped without running."""
    from benchmarks.autotune import pick_winner
    ran = []

    def make_runner(c):
        def run():
            ran.append(c)
            return c
        return run

    modeled = {256: 100.0, 128: 0.0, 64: 50.0}.__getitem__
    timer = _fake_timer({256: 2.0, 128: 1.0, 64: 99.0})
    winner, measured, pruned = pick_winner([256, 128, 64], modeled,
                                           make_runner, timer)
    assert ran == [256, 128]       # default first despite modeled=100
    assert winner == 128
    assert pruned == [64]          # modeled 50 > best measured 1.0
    assert 256 in measured and 64 not in measured


def test_tune_family_keeps_existing_entries_unless_forced():
    from benchmarks.autotune import tune_family
    plat = tuning.platform_name()
    cache = TuningCache()
    cache.put(plat, "bitserial", 256, 4, 128)
    calls = []
    timer = lambda runner: calls.append(runner()) or 1.0
    kw = dict(kernel="bitserial", n=256, bits=4, candidates=[256, 64],
              modeled_s=lambda c: 0.0, make_runner=lambda c: (lambda: c),
              timer=timer)
    assert tune_family(cache, **kw) == 128       # kept, nothing measured
    assert calls == []
    assert tune_family(cache, force=True, **kw) == 256
    assert calls == [256, 64]
    assert cache.lookup(plat, "bitserial", 256, 4) == 256


# ---------------------------------------------------------------------------
# Bit-identity: tuned tiles change time, never results (interpret mode)
# ---------------------------------------------------------------------------
def _with_cache(cache, fn):
    tuning.use_cache(cache)
    try:
        return fn()
    finally:
        tuning.use_cache(None)


def test_bitserial_tuned_tile_bit_identical():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 64))
    run = lambda: bitserial_matmul(x, ql, 3, backend="interpret")
    y_default = run()
    cache = TuningCache()
    cache.put(tuning.platform_name(), "bitserial", 256, 6, 64)
    y_tuned = _with_cache(cache, run)
    assert np.array_equal(np.asarray(y_default), np.asarray(y_tuned))


def test_bitserial_grouped_tuned_tile_bit_identical():
    rng = np.random.default_rng(2)
    qs = quantize_stacked(
        jnp.asarray(rng.normal(size=(4, 32, 128)) * 0.2, jnp.float32),
        bits=6)
    expert_of = jnp.asarray([1, 3, 0], jnp.int32)
    b_sel = jnp.asarray([2, 6, 0], jnp.int32)
    counts = jnp.asarray([2, 1, 4], jnp.int32)
    x = jnp.asarray(rng.normal(size=(3, 2, 32)), jnp.float32)
    run = lambda: bitserial_matmul_grouped(x, qs, expert_of, b_sel,
                                           counts, backend="interpret")
    y_default = run()
    cache = TuningCache()
    cache.put(tuning.platform_name(), "bitserial", 128, 6, 64)
    y_tuned = _with_cache(cache, run)
    assert np.array_equal(np.asarray(y_default), np.asarray(y_tuned))


def test_bitserial_pad_path_with_tuned_tile():
    """Untileable N=200 under an explicit backend pads up to the TUNED
    granularity when one is cached (the satellite fix) and still matches
    the oracle exactly — the stale default-tile pad assumption is gone."""
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 200)) * 0.2
    ql = quantize_linear(w, bits=4)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64))
    y_ref = bitserial_matmul(x, ql, 3, backend="ref")
    cache = TuningCache()
    cache.put(tuning.platform_name(), "bitserial", 200, 4, 128)
    y_tuned = _with_cache(
        cache, lambda: bitserial_matmul(x, ql, 3, backend="interpret"))
    assert y_tuned.shape == y_ref.shape == (2, 200)
    np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_kv_attention_tuned_tile_matches_default():
    """tile_t reorders the online-softmax accumulation across seq tiles,
    so the contract is float-reassociation equivalence (tight allclose),
    not bit identity — and exact agreement with the jnp oracle's
    tolerance class."""
    rng = np.random.default_rng(5)
    s, bits, t, hkv, dh = 2, 4, 128, 1, 32
    kp = jnp.asarray(rng.integers(0, 2**31 - 1,
                                  (s, bits, t, hkv, dh // 32)), jnp.int32)
    sc = jnp.asarray(rng.uniform(0.01, 0.1, (s, t, hkv, 1)), jnp.float32)
    zr = jnp.asarray(rng.uniform(0.0, 1.0, (s, t, hkv, 1)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(s, 1, hkv, dh)), jnp.float32)
    lens = jnp.asarray([[100], [37]], jnp.int32)
    kv_b = jnp.asarray([2, bits], jnp.int32)
    run = lambda: kv_decode_attention(q, kp, sc, zr, kp, sc, zr, lens,
                                      kv_b, bits=bits, backend="interpret")
    y_default = run()
    cache = TuningCache()
    cache.put(tuning.platform_name(), "kv_attention", t, bits, 32)
    y_tuned = _with_cache(cache, run)
    np.testing.assert_allclose(np.asarray(y_default), np.asarray(y_tuned),
                               rtol=1e-5, atol=1e-6)


def test_jl_plan_tuned_u_tile_bit_identical():
    from test_kernels import _plan_setup
    tables, x, _, _ = _plan_setup()                # u=6
    run = lambda: plan_bits(x, tables, 1, backend="interpret")
    b_default = run()
    cache = TuningCache()
    cache.put(tuning.platform_name(), "jl_plan", 6, 0, 2)
    b_tuned = _with_cache(cache, run)
    np.testing.assert_array_equal(np.asarray(b_default),
                                  np.asarray(b_tuned))
    # and both match the oracle
    np.testing.assert_array_equal(
        np.asarray(b_default),
        np.asarray(plan_bits(x, tables, 1, backend="ref")))
