"""Paged bitplane-KV pool: allocator properties, kernel parity, and the
scheduler-level paged-vs-bucketed bit-identity matrix.

The contract under test: the paged cache is a PURE indirection change.
One shared plane pool plus per-slot page tables must produce the same
tokens and per-token effective bits as the bucketed per-slot arrays —
through vmapped ticks, prefill handoffs straddling page boundaries,
speculative rollback, and even page-reclaim preemption (the restart
replays the plan-once target, so the output stream is unchanged). The
allocator side is property-tested: pages never alias between live
owners, frees round-trip, the high watermark bounds peak usage, and
preemption reclaims exactly the victim's pages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.kernels.kv_attention import (TRASH_PAGE, gather_paged_kv,
                                        kv_decode_attention,
                                        kv_decode_attention_paged,
                                        kv_plane_fetches_paged)
from repro.models.attention import (encode_kv_rows, paged_zero_window,
                                    update_kv_planes, update_kv_pool)
from repro.serving import (AdmissionRouter, LatencyModel, PagePool,
                           PriorityClass, QoSPlanner, Request,
                           ServingEngine, SlotScheduler, pages_for_rows)
from repro.serving.kv_cache import (make_paged_pool, make_paged_state,
                                    pool_accounting, stage_bytes,
                                    zero_pool_pages)

BITS = 8


# ---------------------------------------------------------------------------
# Page allocator properties (pure host code — no JAX)
# ---------------------------------------------------------------------------
def test_pages_for_rows_closed_form():
    assert pages_for_rows(0, 4) == 0
    assert pages_for_rows(1, 4) == 1
    assert pages_for_rows(4, 4) == 1
    assert pages_for_rows(5, 4) == 2
    with pytest.raises(ValueError):
        pages_for_rows(3, 0)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 24), st.integers(1, 40))
def test_alloc_free_round_trip(seed, n_pages, n_ops):
    """Random alloc/free interleavings: page 0 never handed out, used +
    free always partitions [1, n_pages), all-or-nothing alloc leaves the
    pool untouched on failure, and draining every live page restores the
    fully-free pool."""
    rng = np.random.default_rng(seed)
    pool = PagePool(n_pages, page_len=4)
    live = []
    for _ in range(n_ops):
        if live and rng.uniform() < 0.4:
            i = int(rng.integers(len(live)))
            pool.free([live.pop(i)])
        else:
            before = pool.n_free
            got = pool.alloc(int(rng.integers(0, n_pages)))
            if got is None:
                assert pool.n_free == before     # failure mutated nothing
            else:
                live.extend(got)
        assert TRASH_PAGE not in live
        assert pool.n_used == len(live)
        assert pool.n_used + pool.n_free == n_pages - 1
        assert len(set(live)) == len(live)       # no id handed out twice
        assert pool.high_watermark >= pool.n_used
        assert pool.high_watermark <= n_pages - 1
    pool.free(live)
    assert pool.n_free == n_pages - 1 and pool.n_used == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 5), st.integers(3, 30))
def test_no_page_aliasing_between_live_owners(seed, n_owners, n_pages):
    """Pages allocated to different owners are pairwise disjoint, and
    ``owned`` reports exactly each owner's live set."""
    rng = np.random.default_rng(seed)
    pool = PagePool(n_pages, page_len=4)
    want = {o: [] for o in range(n_owners)}
    for _ in range(20):
        o = int(rng.integers(n_owners))
        if want[o] and rng.uniform() < 0.3:
            pool.free([want[o].pop()])
        else:
            got = pool.alloc(int(rng.integers(0, 3)), owner=o)
            if got is not None:
                want[o].extend(got)
        sets = [set(want[o]) for o in range(n_owners)]
        for i in range(n_owners):
            assert pool.owned(i) == sorted(want[i])
            for j in range(i + 1, n_owners):
                assert not sets[i] & sets[j]


def test_free_rejects_double_free_and_trash_page():
    pool = PagePool(4, page_len=2)
    ids = pool.alloc(2, owner="a")
    pool.free(ids)
    with pytest.raises(ValueError, match="double free or trash"):
        pool.free(ids[:1])
    with pytest.raises(ValueError, match="double free or trash"):
        pool.free([TRASH_PAGE])


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 4), st.integers(6, 24))
def test_preemption_reclaims_exactly_victims_pages(seed, n_owners, n_pages):
    """The preemption move — ``free(owned(victim))`` — reclaims every
    page of the victim and ONLY those pages; survivors' sets and the
    free count are otherwise untouched."""
    rng = np.random.default_rng(seed)
    pool = PagePool(n_pages, page_len=4)
    for o in range(n_owners):
        pool.alloc(int(rng.integers(1, 3)), owner=o)
    victim = int(rng.integers(n_owners))
    survivors = {o: pool.owned(o) for o in range(n_owners) if o != victim}
    reclaim = pool.owned(victim)
    free_before = pool.n_free
    pool.free(reclaim)
    assert pool.owned(victim) == []
    assert pool.n_free == free_before + len(reclaim)
    for o, pages in survivors.items():
        assert pool.owned(o) == pages


def test_high_watermark_records_peak_not_current():
    pool = PagePool(8, page_len=4)
    a = pool.alloc(5)
    pool.free(a)
    assert pool.n_used == 0
    assert pool.high_watermark == 5
    assert pool.stats()["high_watermark_pages"] == 5


# ---------------------------------------------------------------------------
# Pool state layout, byte accounting, page zeroing
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("tiny-dense")


def test_paged_pool_and_state_layout(tiny_cfg):
    pool = make_paged_pool(tiny_cfg, n_pages=5, page_len=4)
    plane_keys = [k for k in pool if k.endswith("_planes")]
    assert plane_keys
    for k in plane_keys:
        assert pool[k].shape[:3] == (5, BITS, 4)
        assert pool[k].dtype == jnp.int32
        pre = k.rsplit(".", 1)[0]
        for sfx in ("k_scale", "k_zero", "v_scale", "v_zero"):
            assert pool[f"{pre}.{sfx}"].shape[:2] == (5, 4)
    state = make_paged_state(tiny_cfg, 1, 16, page_len=4,
                             dtype=jnp.float32)
    assert state["page_table"].shape == (1, 4)
    assert not np.asarray(state["page_table"]).any()   # boots on trash
    assert not any(k.startswith("kv.") for k in state)  # no buckets left
    sb = stage_bytes({**state, **pool})
    assert sb["pool"] > 0 and sb["kv"] == 0
    assert sb["total"] == sb["pool"] + sb["ssm"] + sb["xkv"] + sb["other"]


def test_pool_accounting_live_vs_allocated(tiny_cfg):
    pool = make_paged_pool(tiny_cfg, n_pages=9, page_len=4)
    alloc = PagePool(9, page_len=4)
    alloc.alloc(3, owner=0)
    acc = pool_accounting(pool, alloc, live_rows=9)
    assert acc["allocated_pages"] == 3
    assert acc["allocated_bytes"] == 3 * acc["page_bytes"]
    assert acc["live_bytes"] == 9 * (acc["page_bytes"] // 4)
    # internal fragmentation: 3 pages cover 12 rows, 9 are live
    assert acc["fragmentation_bytes"] == \
        acc["allocated_bytes"] - acc["live_bytes"]
    assert acc["high_watermark_pages"] == 3
    assert acc["capacity_bytes"] == 9 * acc["page_bytes"]


def test_zero_pool_pages_zeroes_only_the_freed_pages(tiny_cfg):
    rng = np.random.default_rng(5)
    pool = make_paged_pool(tiny_cfg, n_pages=6, page_len=4)
    pool = {k: jnp.asarray(rng.integers(1, 100, v.shape).astype(
        np.int32 if v.dtype == jnp.int32 else np.float32))
        for k, v in pool.items()}
    before = {k: np.asarray(v) for k, v in pool.items()}
    out = zero_pool_pages(pool, [2, 4])
    for k, v in out.items():
        got = np.asarray(v)
        assert not got[2].any() and not got[4].any(), k
        # the power-of-two padding pads with the trash page — page 0 is
        # sacrificial by contract; every OTHER page is untouched
        for p in (1, 3, 5):
            np.testing.assert_array_equal(got[p], before[k][p], err_msg=k)
    assert zero_pool_pages(pool, []) is pool           # no-op on empty


# ---------------------------------------------------------------------------
# Kernel-level parity: pool + page table vs the bucketed per-slot arrays
# ---------------------------------------------------------------------------
def _paged_twin(bucketed, tables, n_pages, page_len):
    """Scatter bucketed per-slot rows (S, ..., T, ...) into a pool
    (NP, ..., page_len, ...) through each slot's page table."""
    arr = np.asarray(bucketed)
    t_axis = 2 if arr.ndim == 5 else 1                 # planes vs scale
    pool = np.zeros((n_pages,) + arr.shape[1:t_axis]
                    + (page_len,) + arr.shape[t_axis + 1:], arr.dtype)
    for s, row in enumerate(tables):
        for i, page in enumerate(row):
            sl_src = [s] + [slice(None)] * (arr.ndim - 1)
            sl_src[t_axis] = slice(i * page_len, (i + 1) * page_len)
            sl_dst = [page] + [slice(None)] * (arr.ndim - 1)
            pool[tuple(sl_dst)] = arr[tuple(sl_src)]
    return jnp.asarray(pool)


def _kv_case(seed, s=3, p=4, page_len=4, hkv=2, hq=4, dh=32, m=2):
    rng = np.random.default_rng(seed)
    t = p * page_len
    kv = jnp.asarray(rng.normal(size=(2, s, t, hkv, dh)), jnp.float32)
    kp, ks, kz = encode_kv_rows(kv[0], BITS)
    vp, vs, vz = encode_kv_rows(kv[1], BITS)
    # a random page assignment: pages [1, n_pages) permuted, no aliasing
    n_pages = s * p + 1
    perm = rng.permutation(np.arange(1, n_pages))
    tables = perm.reshape(s, p)
    args = dict(n_pages=n_pages, page_len=page_len)
    pools = [_paged_twin(a, tables, **args)
             for a in (kp, ks, kz, vp, vs, vz)]
    q = jnp.asarray(rng.normal(size=(s, m, hq, dh)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, t + 1, (s, m)), jnp.int32)
    kv_b = jnp.asarray([BITS, 3, 0, 5][:s], jnp.int32)
    return (q, kp, ks, kz, vp, vs, vz, lens, kv_b,
            pools, jnp.asarray(tables, jnp.int32))


def test_gather_paged_kv_reassembles_bucketed_rows():
    (q, kp, ks, kz, *_rest, pools, pt) = _kv_case(20)
    g_kp, g_ks, g_kz = gather_paged_kv(pools[0], pools[1], pools[2], pt)
    np.testing.assert_array_equal(np.asarray(g_kp), np.asarray(kp))
    np.testing.assert_array_equal(np.asarray(g_ks), np.asarray(ks))
    np.testing.assert_array_equal(np.asarray(g_kz), np.asarray(kz))


def test_paged_ref_bit_identical_to_bucketed_ref():
    """Same rows, page-scattered vs bucketed: the ref backends must be
    BITWISE equal (the gather reproduces the exact bucketed layout, so
    the attention math is the same computation)."""
    (q, kp, ks, kz, vp, vs, vz, lens, kv_b, pools, pt) = _kv_case(21)
    out_b = kv_decode_attention(q, kp, ks, kz, vp, vs, vz, lens, kv_b,
                                bits=BITS, backend="ref")
    out_p = kv_decode_attention_paged(q, *pools, pt, lens, kv_b,
                                      bits=BITS, backend="ref")
    assert np.array_equal(np.asarray(out_p), np.asarray(out_b))
    assert not np.asarray(out_p[2]).any()              # idle slot zeros


def test_paged_kernel_interpret_matches_ref():
    """The Pallas paged kernel (interpret twin): page indirection +
    dead-tile pinning vs the gather oracle, mixed read precisions."""
    (q, *_b, lens, kv_b, pools, pt) = _kv_case(22)
    out_r = kv_decode_attention_paged(q, *pools, pt, lens, kv_b,
                                      bits=BITS, backend="ref")
    out_i = kv_decode_attention_paged(q, *pools, pt, lens, kv_b,
                                      bits=BITS, backend="interpret")
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                               atol=1e-5)
    assert not np.asarray(out_i[2]).any()


def test_paged_vmap_flattens_and_shares_one_pool():
    """vmapping the paged dispatch (the scheduler's slot vmap) flattens
    the slot axes onto one launch while the pool rides through
    UNBATCHED; batching a pool operand is a contract violation."""
    (q, *_b, lens, kv_b, pools, pt) = _kv_case(23, s=4, m=1)
    flat = kv_decode_attention_paged(q, *pools, pt, lens, kv_b,
                                     bits=BITS, backend="ref")

    def shaped(a):
        return a.reshape((2, 2) + a.shape[1:])

    nested = jax.vmap(
        lambda qq, tt, ll, bb: kv_decode_attention_paged(
            qq, *pools, tt, ll, bb, bits=BITS, backend="ref"))(
        shaped(q), shaped(pt), shaped(lens), shaped(kv_b))
    assert np.array_equal(np.asarray(nested.reshape(flat.shape)),
                          np.asarray(flat))

    with pytest.raises(ValueError, match="unbatched"):
        jax.vmap(lambda kp: kv_decode_attention_paged(
            q, kp, *pools[1:], pt, lens, kv_b,
            bits=BITS, backend="ref"))(
            jnp.stack([pools[0], pools[0]]))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 6), st.integers(2, 4),
       st.integers(1, 4), st.integers(1, 8))
def test_paged_fetch_walk_closed_form(seed, s, p, page_len, bits):
    """The paged traffic walk equals  sum_busy n_live_tiles * kv_b
    + n_idle_runs  when live pages never alias (the allocator
    invariant): dead tiles pin to the last live block (zero DMA) and
    each idle run costs one trash-page block."""
    rng = np.random.default_rng(seed)
    kv_b = rng.integers(0, bits + 1, size=s)
    lens = rng.integers(0, p * page_len + 1, size=(s, 1))
    # distinct non-trash pages across every slot — no aliasing
    pages = rng.permutation(np.arange(1, s * p + 1)).reshape(s, p)
    walked = kv_plane_fetches_paged(pages, lens, kv_b,
                                    page_len=page_len, bits=bits)
    busy = kv_b > 0
    nl = np.maximum(1, -(-np.maximum(1, lens[:, 0]) // page_len))
    total = int(np.sum(nl[busy] * kv_b[busy]))
    idle_runs, prev_idle = 0, False
    for f in busy:
        if not f and not prev_idle:
            idle_runs += 1
        prev_idle = not f
    assert walked == total + idle_runs, (kv_b, lens[:, 0], pages)


def test_paged_write_and_zero_window_round_trip():
    """``update_kv_pool`` lands rows [pos, pos+M) on the owner's pages
    only (bit-identical to the bucketed ``update_kv_planes`` twin),
    rows whose table entry is UNALLOCATED (0) land on the trash page,
    and ``paged_zero_window`` erases exactly the window."""
    rng = np.random.default_rng(24)
    s, p, page_len, hkv, dh, m = 2, 4, 4, 2, 32, 3
    t = p * page_len
    # slot 1's last logical page is unallocated (entry 0 = trash)
    tables = np.asarray([[1, 2, 3, 7], [4, 5, 6, 0]], np.int32)
    shapes = dict(n_pages=8, page_len=page_len)
    zero_b = jnp.zeros((s, BITS, t, hkv, 1), jnp.int32)
    zero_s = jnp.zeros((s, t, hkv, 1), jnp.float32)
    pools = [_paged_twin(a, tables, **shapes)
             for a in (zero_b, zero_s, zero_s, zero_b, zero_s, zero_s)]
    k_new = jnp.asarray(rng.normal(size=(s, m, hkv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(s, m, hkv, dh)), jnp.float32)
    pos = jnp.asarray([3, 11], jnp.int32)              # both straddle
    pools = update_kv_pool(*pools, jnp.asarray(tables), k_new, v_new,
                           pos, bits=BITS)
    # bucketed twin of slot 0's write
    buck = update_kv_planes(zero_b[:1], zero_s[:1], zero_s[:1],
                            zero_b[:1], zero_s[:1], zero_s[:1],
                            k_new[:1], v_new[:1], jnp.int32(3), bits=BITS)
    g = gather_paged_kv(pools[0], pools[1], pools[2],
                        jnp.asarray(tables))
    np.testing.assert_array_equal(np.asarray(g[0][0]),
                                  np.asarray(buck[0][0]))
    np.testing.assert_array_equal(np.asarray(g[1][0]),
                                  np.asarray(buck[1][0]))
    # slot 1's rows 11 (page 6) land; rows 12-13 hit the unallocated
    # table entry and are absorbed by the trash page — slot 0's pages
    # (checked bit-exact above) are never touched by the collision
    g1 = np.asarray(g[0][1])
    assert g1[:, 11:12].any() and not g1[:, :11].any()
    assert np.asarray(pools[0][TRASH_PAGE]).any()
    # rollback erase: zero rows [3, 3+2) of slot 0 only; row 5 survives
    pools = paged_zero_window(*pools, jnp.asarray(tables[:1]),
                              jnp.asarray([3], jnp.int32), 2)
    g2 = gather_paged_kv(pools[0], pools[1], pools[2],
                         jnp.asarray(tables))
    g0 = np.asarray(g2[0][0])
    assert not g0[:, 3:5].any()
    assert g0[:, 5].any()
    np.testing.assert_array_equal(
        np.asarray(g2[0][1])[:, :12], g1[:, :12])      # slot 1 untouched


# ---------------------------------------------------------------------------
# Admission router + queue-depth TTFT pricing (satellite: the fleet seam)
# ---------------------------------------------------------------------------
def _req(rid, plen=4, tpot=None, ttft=None):
    return Request(rid=rid, prompt=np.ones((plen,), np.int32), max_new=2,
                   tpot_budget_s=tpot, ttft_budget_s=ttft)


def test_latency_model_prices_prefill_queue_depth():
    lm = LatencyModel(bytes_per_bit=1e6)
    own = lm.ttft(4.0, prompt_len=32, prefill_chunk=8)
    queued = lm.ttft(4.0, prompt_len=32, prefill_chunk=8,
                     queued_launches=6)
    assert own == pytest.approx(4 * lm.tpot(4.0))
    assert queued == pytest.approx(10 * lm.tpot(4.0))
    assert queued > own


def test_planner_ttft_guard_includes_queue_depth():
    """A precision that fits an idle worker must be rejected when the
    assigned worker's queue pushes the predicted TTFT past budget."""
    lm = LatencyModel(bytes_per_bit=1e6, overhead_s=1e-3)
    qos = QoSPlanner([3.5, 4.0, 4.5], lm)
    budget = lm.ttft(4.5, 16, 8) * 1.5
    idle = qos.plan(1.0, prompt_len=16, ttft_budget_s=budget,
                    prefill_chunk=8, queued_launches=0)
    busy = qos.plan(1.0, prompt_len=16, ttft_budget_s=budget,
                    prefill_chunk=8, queued_launches=50)
    assert idle == 4.5
    assert busy == 3.5                                 # guard forced min


def test_router_classify_and_drain_order():
    router = AdmissionRouter(prefill_workers=2)
    fast = _req(0, ttft=0.2)
    mid = _req(1, tpot=0.08)
    slow = _req(2)                                     # no budgets: batch
    assert router.submit(slow).name == "batch"
    assert router.submit(mid).name == "standard"
    assert router.submit(fast).name == "interactive"
    assert len(router) == 3
    assert [router.next_request().rid for _ in range(3)] == [0, 1, 2]
    # requeue puts a preempted request back at the HEAD of its class
    router.submit(_req(3, tpot=0.08))
    router.requeue(mid)
    assert router.next_request().rid == 1


def test_router_routes_least_loaded_worker_and_reports_depth():
    router = AdmissionRouter(prefill_workers=2)
    w0, ahead0 = router.route_prefill(4)
    assert ahead0 == 0
    w1, ahead1 = router.route_prefill(2)
    assert w1 != w0 and ahead1 == 0                    # fresh worker
    w2, ahead2 = router.route_prefill(1)
    assert w2 == w1 and ahead2 == 2                    # behind the 2
    router.finish_prefill(w1, 2)
    assert router.queue_depth(w1) == 1
    assert router.queue_depth() == 1                   # least-loaded view


def test_router_pick_victim_least_urgent_youngest():
    router = AdmissionRouter(prefill_workers=1)
    cands = [(0, _req(0, ttft=0.2), 5),                # interactive
             (1, _req(1), 3),                          # batch, older
             (2, _req(2), 7)]                          # batch, youngest
    assert router.pick_victim(cands) == 2
    assert router.pick_victim([]) is None


# ---------------------------------------------------------------------------
# Scheduler-level parity matrix: paged == bucketed, token for token
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def overlay_engines(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return {
        True: ServingEngine(cfg, params, model, kv_overlay=True,
                            use_async=True),
        False: ServingEngine(cfg, params, model, kv_overlay=True,
                             use_async=False),
    }


def _requests(cfg, n=5, seed=1):
    rng = np.random.default_rng(seed)
    budgets = [6e-3, 5.2e-3, 4.6e-3, 1e-3, 6e-3]
    # prompt lengths 3..6 with page_len=4: prompts that fit one page,
    # end exactly on a boundary, and straddle into a second page
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        (3 + i % 4,)).astype(np.int32),
                    max_new=4 + i % 3, tpot_budget_s=budgets[i % 5])
            for i in range(n)]


_RUNS = {}                                             # (variant, paged, n_pages)


def _sched_run(tiny_bundle, engines, variant, *, paged, use_async=True,
               spec_k=None, n_pages=None):
    key = (variant, paged, n_pages)
    if key in _RUNS:
        return _RUNS[key]
    cfg, _, model, _ = tiny_bundle
    planner = QoSPlanner(sorted(model.adaptations),
                         LatencyModel(bytes_per_bit=1e6), spec_k=spec_k)
    kw = dict(slots=2, max_prompt=8, max_new=6, chunk=3, spec_k=spec_k)
    if paged:
        kw.update(paged=True, page_len=4, n_pages=n_pages)
    sched = SlotScheduler(engines[use_async], planner, **kw)
    done = sorted(sched.run(_requests(cfg)), key=lambda r: r.rid)
    _RUNS[key] = (done, sched)
    return done, sched


VARIANTS = [("async", dict(use_async=True)),
            ("sync", dict(use_async=False)),
            ("spec2", dict(use_async=True, spec_k=2))]


@pytest.mark.parametrize("variant,kw", VARIANTS,
                         ids=[v for v, _ in VARIANTS])
def test_scheduler_paged_vs_bucketed_bit_identity(tiny_bundle,
                                                  overlay_engines,
                                                  variant, kw):
    """Async/sync pipelining and speculative windows, prompts straddling
    page boundaries: the paged scheduler's tokens, per-token effective
    bits, and admitted targets are BITWISE those of the bucketed one."""
    base, _ = _sched_run(tiny_bundle, overlay_engines, variant,
                         paged=False, **kw)
    paged, sp = _sched_run(tiny_bundle, overlay_engines, variant,
                           paged=True, **kw)
    assert len(base) == len(paged) == 5
    for b, p in zip(base, paged):
        assert b.target == p.target, b.rid
        assert np.array_equal(b.tokens, p.tokens), b.rid
        assert np.array_equal(b.effective_bits, p.effective_bits), b.rid
    stats = sp.paged_stats()
    assert stats["preemptions"] == 0                   # ample pool
    assert stats["allocated_pages"] == 0               # all retired
    assert stats["live_rows"] == 0
    assert 0 < stats["high_watermark_pages"] <= sp.page_alloc.n_pages - 1


def test_scheduler_tight_pool_preempts_and_stays_bit_identical(
        tiny_bundle, overlay_engines):
    """A pool too small for both slots' worst case forces page-reclaim
    preemption — and the plan-once restart keeps the output stream
    BITWISE unchanged (preemption is a scheduling event, not a model
    event)."""
    base, _ = _sched_run(tiny_bundle, overlay_engines, "async",
                         paged=False, use_async=True)
    paged, sp = _sched_run(tiny_bundle, overlay_engines, "tight",
                           paged=True, use_async=True, n_pages=6)
    assert sp.preemptions > 0
    for b, p in zip(base, paged):
        assert b.target == p.target, b.rid
        assert np.array_equal(b.tokens, p.tokens), b.rid
        assert np.array_equal(b.effective_bits, p.effective_bits), b.rid
    stats = sp.paged_stats()
    assert stats["high_watermark_pages"] <= 5          # never over budget
    assert stats["allocated_pages"] == 0


def test_scheduler_rejects_request_that_can_never_fit(tiny_bundle,
                                                      overlay_engines):
    cfg, _, model, _ = tiny_bundle
    planner = QoSPlanner(sorted(model.adaptations),
                         LatencyModel(bytes_per_bit=1e6))
    sched = SlotScheduler(overlay_engines[True], planner, slots=2,
                          max_prompt=8, max_new=6, chunk=3, paged=True,
                          page_len=4, n_pages=3)
    with pytest.raises(ValueError, match="enlarge n_pages"):
        sched.submit(_requests(cfg, n=1)[0])
