"""Bit-plane overlay substrate: exactness, prefix property, deltas."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitplane import (delta_weight, materialize,
                                 materialize_stacked, quantize_linear,
                                 quantize_stacked, truncate_overlay,
                                 truncate_stacked)
from repro.core.quantizer import (dequantize, quantization_mse,
                                  quantize_channelwise)


def _w(key, k=64, n=48, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(key), (k, n)) * scale


def test_full_precision_materialize_exact():
    w = _w(0)
    ql = quantize_linear(w, bits=8)
    q, s, z = quantize_channelwise(w, 8)
    np.testing.assert_allclose(materialize(ql, 8), dequantize(q, s, z),
                               atol=1e-5)


def test_monotone_error_in_bits():
    w = _w(1)
    ql = quantize_linear(w, bits=8)
    errs = [float(jnp.mean(jnp.abs(materialize(ql, b) - w)))
            for b in range(2, 9)]
    assert all(errs[i + 1] < errs[i] for i in range(len(errs) - 1)), errs


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 7))
def test_prefix_property(seed, l):
    """Any b-bit prefix equals independently truncated codes (hypothesis)."""
    w = _w(seed % 97, k=32, n=16)
    ql = quantize_linear(w, bits=8)
    h = l + 1
    d1 = materialize(ql, h) - materialize(ql, l)
    d2 = delta_weight(ql, l, h)
    np.testing.assert_allclose(d1, d2, atol=1e-4)


def test_truncate_overlay_preserves_prefix():
    w = _w(2)
    ql = quantize_linear(w, 6)
    qt = truncate_overlay(ql, 4)
    assert qt.planes.shape[0] == 4
    for b in (2, 3, 4):
        np.testing.assert_allclose(materialize(qt, b), materialize(ql, b),
                                   atol=1e-6)


def test_stacked_matches_per_expert():
    e, k, n = 3, 32, 16
    w = jax.random.normal(jax.random.PRNGKey(5), (e, k, n)) * 0.2
    qs = quantize_stacked(w, 6)
    full = materialize_stacked(qs, 4)
    for i in range(e):
        ref = materialize(quantize_linear(w[i], 6), 4)
        np.testing.assert_allclose(full[i], ref, atol=1e-5)
    qt = truncate_stacked(qs, 4)
    np.testing.assert_allclose(materialize_stacked(qt, 4), full, atol=1e-6)


def test_quantization_mse_decreases_with_bits():
    w = _w(3)
    mses = [float(quantization_mse(w, b)) for b in (3, 4, 5, 6, 8)]
    assert all(mses[i + 1] < mses[i] for i in range(len(mses) - 1))


def test_memory_overlay_cost():
    """The Any-Precision property: adaptation set costs ONE parent model."""
    w = _w(4, k=128, n=64)
    ql = quantize_linear(w, bits=6)
    plane_bytes = int(np.prod(ql.planes.shape)) * 4
    # 6 bit-planes of 128x64 -> packed int32 words
    assert plane_bytes == 6 * (128 // 32) * 64 * 4
    # per-precision traffic is proportional to b
    ba = ql.bytes_at
    assert ba[6] == 2 * ba[3]
