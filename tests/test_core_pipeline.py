"""DP-LLM offline pipeline: allocator, Phase 2, thresholds, estimators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.allocator import allocate_precisions, uniform_allocation
from repro.core.estimators import (estimate, fit_estimator, fit_gamma,
                                   fit_linear, make_g, sample_projection)
from repro.core.thresholds import candidate_pair, threshold_from_quantile


# ---------------------------------------------------------------------------
# Allocator (Phase 1 / static baselines)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.floats(3.2, 5.8))
def test_allocator_respects_budget(seed, budget):
    rng = np.random.default_rng(seed)
    n = 12
    bits = [3, 4, 5, 6]
    # monotone-decreasing costs in bits
    base = rng.uniform(0.1, 10.0, size=(n, 1))
    cost = base * np.array([[8.0, 4.0, 2.0, 1.0]])
    sizes = rng.integers(1_000, 100_000, size=n)
    alloc = allocate_precisions(cost, sizes, bits, budget)
    avg = float(np.sum(np.array(alloc) * sizes) / np.sum(sizes))
    assert avg <= budget + 1e-9
    assert all(b in bits for b in alloc)


def test_allocator_prefers_sensitive_layers():
    # layer 0 is 100x more sensitive -> gets more bits at equal size
    cost = np.array([[100.0, 50.0, 25.0, 12.0],
                     [1.0, 0.5, 0.25, 0.12]])
    alloc = allocate_precisions(cost, [10, 10], [3, 4, 5, 6], 4.5)
    assert alloc[0] > alloc[1]


def test_allocator_lower_bound():
    cost = np.ones((4, 4)) * np.array([[4, 3, 2, 1.0]])
    alloc = allocate_precisions(cost, [1, 1, 1, 1], [3, 4, 5, 6], 6.0,
                                min_avg_bits=4.5)
    avg = np.mean(alloc)
    assert avg >= 4.5 - 1e-9


def test_uniform_allocation():
    assert uniform_allocation(5, 4) == [4] * 5


# ---------------------------------------------------------------------------
# Estimators (paper §5)
# ---------------------------------------------------------------------------
def test_linear_fit_recovers_slope():
    rng = np.random.default_rng(0)
    xn = rng.uniform(1, 10, 500)
    err = 2.5 * xn + 0.3 + rng.normal(0, 0.01, 500)
    a, b, r2 = fit_linear(xn, err)
    assert abs(a - 2.5) < 0.02 and abs(b - 0.3) < 0.1 and r2 > 0.99


def test_hybrid_choice_by_r2():
    rng = np.random.default_rng(1)
    xn = rng.uniform(1, 10, 200)
    err_lin = 3 * xn + rng.normal(0, 0.01, 200)
    err_rand = rng.uniform(0, 10, 200)
    g = np.zeros((4, 8))
    f1 = fit_estimator(err_lin, xn, err_lin, g)
    f2 = fit_estimator(err_rand, xn, np.abs(err_rand), g)
    assert f1.kind == "linear" and f2.kind == "jl"


def test_jl_estimate_tracks_true_error():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    dw = jax.random.normal(k1, (128, 96)) * 0.05
    a = sample_projection(k2, 64, 96)
    g = make_g(a, dw)
    xs = jax.random.normal(k3, (200, 128))
    true = np.asarray(jnp.linalg.norm(xs @ dw, axis=-1))
    raw = np.asarray(jnp.linalg.norm(xs @ g.T, axis=-1))
    gamma = fit_gamma(raw, true)
    rel = np.abs(gamma * raw - true) / true
    # paper: k=64 keeps estimation error within ~15% w.h.p.
    assert np.quantile(rel, 0.91) < 0.25


def test_estimate_batch_max_semantics():
    from repro.core.estimators import EstimatorFit
    fit = EstimatorFit(kind="linear", r2=1.0, a=1.0, b=0.0)
    x = jnp.stack([jnp.ones(16), 2 * jnp.ones(16)])
    # max over batch -> norm of the larger row
    assert float(estimate(fit, x)) == pytest.approx(
        float(jnp.linalg.norm(2 * jnp.ones(16))), rel=1e-5)


# ---------------------------------------------------------------------------
# Thresholds (Phase 3)
# ---------------------------------------------------------------------------
def test_candidate_pair():
    assert candidate_pair(3.2, 3, 6) == (3, 4)
    assert candidate_pair(5.0, 3, 6) == (5, 5)
    assert candidate_pair(7.2, 3, 6) == (6, 6)


@settings(max_examples=20, deadline=None)
@given(st.floats(3.05, 3.95), st.integers(0, 1000))
def test_threshold_quantile_selects_expected_fraction(p, seed):
    """r-quantile threshold -> ~(p-l) of calibration tokens pick h-bit."""
    rng = np.random.default_rng(seed)
    errs = rng.uniform(0, 1, 5000)
    t = threshold_from_quantile(errs, p, 3)
    frac_high = float(np.mean(errs > t))
    assert abs(frac_high - (p - 3)) < 0.05


# ---------------------------------------------------------------------------
# End-to-end pipeline artifacts (shared tiny build)
# ---------------------------------------------------------------------------
def test_phase2_hits_target_precision(tiny_bundle):
    _, _, model, _ = tiny_bundle
    for t, aset in model.adaptations.items():
        assert abs(aset.avg_p - t) < 0.35, (t, aset.avg_p)


def test_phase1_respects_memory_budget(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    from repro.models import linear_units
    units = linear_units(cfg)
    sizes = np.array([np.prod(params[u.path].shape) for u in units])
    bits = np.array([model.max_bits[u.path] for u in units])
    avg = float(np.sum(bits * sizes) / np.sum(sizes))
    assert avg <= model.memory_budget_bits + 1e-6


def test_static_baselines_match_targets(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    from repro.models import linear_units
    units = linear_units(cfg)
    sizes = np.array([np.prod(params[u.path].shape) for u in units])
    for method in ("llm_mq", "hawq_v2"):
        for t, table in model.static_tables[method].items():
            bits = np.array([table[u.path] for u in units])
            avg = float(np.sum(bits * sizes) / np.sum(sizes))
            if method == "llm_mq":
                # Eq. 8's lower bound can overshoot by one unit upgrade
                # (the paper's b_targmin sweep is approximate too)
                assert t - 0.75 <= avg <= t + 0.5, (t, avg)
            else:
                assert avg <= t + 1e-6, (t, avg)


def test_estimator_census_is_hybrid(tiny_bundle):
    _, _, model, _ = tiny_bundle
    cen = model.adaptations[3.5].estimator_census()
    assert cen["linear"] + cen["jl"] > 0
