"""Self-speculative decoding: parity, rollback, launch invariants, QoS.

The contract under test: drafting k-1 tokens at the overlay's 2-bit
floor and re-scoring the window in one batched verify launch must be a
PURE latency optimization — greedy longest-prefix acceptance keeps
``generate`` token- AND effective-bits-identical to baseline decode in
every mode, sync or async, for every k (k=1 is the verify-only
degenerate case). Everything observable — KV/SSM rollback after a
mid-window rejection, the async decision-carry rewind, the per-token
bit attribution — is covered by that identity; the launch counters and
host-sync/no-retrace invariants pin down the "optimization" half.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                           Request, ServingEngine, SlotScheduler,
                           rollback_decode_state)

MODES = ("dynamic", "static:llm_mq", "max", "exact")


@pytest.fixture(scope="module")
def engines(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return {"async": ServingEngine(cfg, params, model),
            "sync": ServingEngine(cfg, params, model, use_async=False)}


@pytest.fixture(scope="module")
def prompt(tiny_bundle):
    cfg = tiny_bundle[0]
    rng = np.random.default_rng(11)
    return rng.integers(1, cfg.vocab_size, (2, 3)).astype(np.int32)


_BASE = {}


def _baseline(engines, which, mode, prompt):
    if (which, mode) not in _BASE:
        _BASE[(which, mode)] = engines[which].generate(prompt, 6, 4.0,
                                                       mode=mode)
    return _BASE[(which, mode)]


@pytest.mark.parametrize("k", (1, 2, 4))
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("which", ("async", "sync"))
def test_spec_generate_identical_to_baseline(engines, prompt, which,
                                             mode, k):
    """Tokens and per-token effective bits match baseline decode exactly.

    The bits identity is the strong half: it proves accepted tokens are
    attributed the VERIFY launch's planner-assigned bits (never the
    2-bit draft floor), that the async decision carry rewinds to the
    last accepted row's plan, and that KV/SSM rollback after a
    mid-window rejection leaves no trace in later steps.
    """
    out_b, eb_b = _baseline(engines, which, mode, prompt)
    out_s, eb_s = engines[which].generate(prompt, 6, 4.0, mode=mode,
                                          spec_k=k)
    assert np.array_equal(out_b, out_s)
    np.testing.assert_allclose(eb_b, eb_s, atol=1e-5)
    s = engines[which].last_spec
    assert s["k"] == k
    assert s["verify_launches"] == s["windows"]
    assert s["emitted_raw"] == s["windows"] + s["accepted"]
    if k == 1:      # verify-only windows: no drafts offered, 1 tok/launch
        assert s["accepted"] == 0
        assert s["launches_per_token"] == 1.0


def test_spec_launch_invariant(engines, tiny_bundle):
    """Closed form: launches/emitted == windows / (windows + accepted),
    and any acceptance at all pushes it below one launch per token.

    Acceptance is data-dependent on the tiny model, so probe a few
    prompts (same shape — zero retrace) until one accepts; the closed
    form is asserted for EVERY probe, accepting or not."""
    cfg = engines["async"].cfg
    eng = engines["async"]
    found = None
    for seed in range(20, 28):
        p = np.random.default_rng(seed).integers(
            1, cfg.vocab_size, (2, 3)).astype(np.int32)
        eng.generate(p, 16, 4.0, spec_k=4)
        s = eng.last_spec
        w, a = s["windows"], s["accepted"]
        assert s["verify_launches"] == w
        assert s["launches_per_token"] == pytest.approx(w / (w + a))
        if a > 0 and found is None:
            found = s
            break
    # the tiny model's 2-bit drafts do land on greedy continuations —
    # the sub-one-launch regime exists, not just the closed form
    assert found is not None, "no acceptance across 8 probe prompts"
    assert found["launches_per_token"] < 1.0
    assert 0.0 < found["acceptance_rate"] <= 1.0


def test_spec_mid_window_rejection_occurs(engines, prompt):
    """The parity matrix above must actually exercise rejection paths:
    with k=4 the tiny model's drafts are NOT all accepted, so the
    KV/SSM rollback and carry rewind ran under a partial window."""
    eng = engines["async"]
    eng.generate(prompt, 8, 4.0, spec_k=4)
    s = eng.last_spec
    assert s["accepted"] < s["windows"] * (s["k"] - 1)


def test_spec_host_syncs_o1(engines, prompt):
    """One spec generate syncs the host exactly twice (tokens + packed
    bits/counters) regardless of max_new or k."""
    eng = engines["async"]
    eng.generate(prompt, 6, 4.0, spec_k=2)          # warm
    h0 = eng.host_syncs
    eng.generate(prompt, 6, 4.0, spec_k=2)
    assert eng.host_syncs - h0 == 2


def test_spec_no_retrace_across_targets_and_k(engines, prompt):
    """One compiled spec loop per (mode, k, bucket): sweeping targets
    and max_new within a bucket must not retrace or recompile."""
    eng = engines["async"]
    for k in (2, 4):
        eng.generate(prompt, 6, 3.5, spec_k=k)      # warm both k loops
    before = dict(eng.trace_counts)
    calls0 = eng.call_counts.get("spec_loop", 0)
    n = 0
    for k in (2, 4):
        for t in (3.5, 4.0, 4.5):
            eng.generate(prompt, 6, t, spec_k=k)
            eng.generate(prompt, 4, t, spec_k=k)
            n += 2
    assert eng.trace_counts == before
    assert eng.call_counts["spec_loop"] == calls0 + n


def test_spec_bits_never_draft_floor(engines, prompt):
    """Attribution regression: in max mode every emitted token's bits
    sit at the overlay ceiling — if draft-tick bits leaked into the
    per-token stream, 2-bit entries would show up."""
    eng = engines["async"]
    _, eb = eng.generate(prompt, 8, 4.0, mode="max", spec_k=4)
    assert min(eb) > 2.5


def test_rollback_decode_state_unit():
    """Direct check of the rollback algebra on a synthetic state."""
    L, W, b = 10, 3, 1
    kv = jnp.arange(b * L * 2 * 4, dtype=jnp.float32).reshape(b, L, 2, 4)
    kv = kv.at[:, 8:].set(0.0)          # zero-rows invariant: rows >= pos
    state = {"pos": jnp.int32(8),                   # post-verify: 5 + W
             "kv.0.k": kv,
             "ssm.0.conv": jnp.ones((b, 4), jnp.float32) * 9.0}
    snaps = {"ssm.0.conv": jnp.stack(
        [jnp.full((b, 4), float(m)) for m in range(W)])}   # (W, b, 4)
    out = rollback_decode_state(state, snaps, n_keep=2, window=W)
    assert int(out["pos"]) == 7                     # 8 - 3 + 2
    np.testing.assert_array_equal(np.asarray(out["kv.0.k"][0, 7:]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["kv.0.k"][:, :7]),
                                  np.asarray(kv[:, :7]))   # kept rows
    np.testing.assert_array_equal(np.asarray(out["ssm.0.conv"]),
                                  1.0)              # snapshot row n_keep-1


def test_scheduler_spec_parity_and_tracker(engines, tiny_bundle):
    """spec_k scheduler == baseline scheduler: same tokens, same bits,
    same tracker attribution; acceptance counters feed the planner."""
    cfg, _, model, _ = tiny_bundle
    eng = engines["async"]
    rng = np.random.default_rng(5)
    mk = lambda: [Request(rid=i,
                          prompt=rng.integers(1, cfg.vocab_size,
                                              (ln,)).astype(np.int32),
                          max_new=mn, tpot_budget_s=1.0)
                  for i, (ln, mn) in enumerate([(3, 5), (1, 4), (6, 5)])]
    reqs = mk()

    def run(spec_k):
        tracker = QueryBitTracker()
        planner = QoSPlanner(sorted(model.adaptations),
                             LatencyModel(bytes_per_bit=1e6),
                             spec_k=spec_k)
        sched = SlotScheduler(eng, planner, slots=2, max_prompt=8,
                              max_new=5, chunk=3, tracker=tracker,
                              spec_k=spec_k)
        done = sorted(sched.run([Request(rid=r.rid, prompt=r.prompt,
                                         max_new=r.max_new,
                                         tpot_budget_s=r.tpot_budget_s)
                                 for r in reqs]), key=lambda r: r.rid)
        return done, tracker, sched

    base, tr_b, _ = run(None)
    spec, tr_s, sched = run(2)
    for rb, rs in zip(base, spec):
        assert np.array_equal(rb.tokens, rs.tokens)
        np.testing.assert_allclose(rb.effective_bits, rs.effective_bits,
                                   atol=1e-5)
    # retirement ORDER may differ (spec slots advance at variable rates),
    # but the per-query attribution must be the same multiset
    np.testing.assert_allclose(sorted(tr_b.per_query_bits),
                               sorted(tr_s.per_query_bits), atol=1e-5)
    assert sched.spec_windows > 0
    # the chunk's acceptance counters reached the planner's EMA
    assert sched.planner.acceptance_ema >= 0.0
    assert sched.spec_accepted >= 0.0


def test_scheduler_spec_requires_prefill(engines, tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    legacy = ServingEngine(cfg, params, model, prefill_chunk=0)
    planner = QoSPlanner(sorted(model.adaptations),
                         LatencyModel(bytes_per_bit=1e6))
    with pytest.raises(ValueError, match="prefill"):
        SlotScheduler(legacy, planner, spec_k=2)


def test_latency_model_spec_tpot():
    lm = LatencyModel(bytes_per_bit=1e9, overhead_s=0.0)
    # k=1 (and acceptance=0 at k=1) degenerates to plain tpot
    assert lm.spec_tpot(4.0, 1, 0.7) == pytest.approx(lm.tpot(4.0))
    # zero acceptance: full window cost buys exactly one token
    assert lm.spec_tpot(4.0, 3, 0.0) == pytest.approx(
        2 * lm.tpot(2.0) + lm.tpot(4.0))
    # good acceptance with cheap drafts beats the plain tick
    assert lm.spec_tpot(4.0, 4, 1.0) < lm.tpot(4.0)
    # acceptance clamps: out-of-range inputs don't corrupt the model
    assert lm.spec_tpot(4.0, 4, 2.0) == pytest.approx(
        lm.spec_tpot(4.0, 4, 1.0))


def test_qos_planner_spec_admission():
    """Observed acceptance moves admission: a workload whose drafts land
    admits a higher precision into the SAME TPOT budget."""
    lm = LatencyModel(bytes_per_bit=1e9, overhead_s=0.0)
    targets = [3.5, 4.0, 4.5]
    budget = lm.tpot(4.0)               # plain: 4.0 fits, 4.5 doesn't
    assert QoSPlanner(targets, lm).plan(budget) == 4.0
    p = QoSPlanner(targets, lm, spec_k=4)
    # cold EMA (acceptance 0): spec windows cost more per token, so the
    # planner is conservative rather than optimistic
    assert p.plan(budget) <= 4.0
    for _ in range(60):
        p.observe_acceptance(1.0)
    assert p.acceptance_ema > 0.95
    assert p.plan(budget) == 4.5
    # EMA input is clamped
    p.observe_acceptance(7.0)
    assert p.acceptance_ema <= 1.0
