"""Checkpointing (atomicity, retention, async) + data pipeline."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointManager
from repro.data import DataConfig, ShardedBatchIterator, load_corpus
from repro.optim import adamw


def _tree():
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((4,))}
    return {"params": params, "opt": adamw.init(params)}


def test_roundtrip_with_template():
    with tempfile.TemporaryDirectory() as td:
        ck = Checkpointer(td, async_save=False)
        tree = _tree()
        ck.save(3, tree)
        restored, step = ck.restore(tree)
        assert step == 3
        np.testing.assert_allclose(restored["params"]["a"],
                                   tree["params"]["a"])
        assert int(restored["opt"].step) == 0


def test_template_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as td:
        ck = Checkpointer(td, async_save=False)
        ck.save(1, {"w": jnp.ones((3,))})
        with pytest.raises(ValueError):
            ck.restore({"w": jnp.ones((4,))})


def test_atomic_commit_ignores_tmp():
    with tempfile.TemporaryDirectory() as td:
        ck = Checkpointer(td, async_save=False)
        ck.save(1, {"w": jnp.ones((2,))})
        os.makedirs(os.path.join(td, "step_00000009.tmp"))
        assert ck.latest_step() == 1     # torn save never counts


def test_retention_and_resume():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, save_every=2, keep=2)
        tree = _tree()
        for s in range(1, 9):
            mgr.maybe_save(s, tree)
        mgr.wait()
        assert mgr.ckpt.available_steps() == [6, 8]
        _, step = mgr.restore_latest(tree)
        assert step == 8


def test_restore_latest_fresh_start():
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        tree, step = mgr.restore_latest(_tree())
        assert step == 0


def test_corpus_splits_disjoint_and_deterministic():
    a1 = load_corpus("calibration", 50_000)
    a2 = load_corpus("calibration", 50_000)
    b = load_corpus("eval", 50_000)
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1[:1000], b[:1000])


def test_pipeline_determinism_and_seek():
    cfg = DataConfig(seq_len=16, global_batch=4, seed=7)
    it1 = ShardedBatchIterator(cfg)
    batches1 = [next(it1) for _ in range(3)]
    it1.close()
    it2 = ShardedBatchIterator(cfg)
    it2.seek(2)                      # resume at step 2 (restart scenario)
    t2, l2 = next(it2)
    it2.close()
    np.testing.assert_array_equal(t2, batches1[2][0])


def test_pipeline_host_sharding():
    cfg = DataConfig(seq_len=16, global_batch=4, seed=7)
    itA = ShardedBatchIterator(cfg, host_id=0, num_hosts=2)
    itB = ShardedBatchIterator(cfg, host_id=1, num_hosts=2)
    a, _ = next(itA)
    b, _ = next(itB)
    itA.close(); itB.close()
    assert a.shape == (2, 16)
    assert not np.array_equal(a, b)   # different host shards
