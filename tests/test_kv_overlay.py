"""Dynamic-precision KV cache: overlay round trips, kernel parity, and
the engine-level bit-identity matrix.

The contract under test: writes always store the FULL kv_plane_bits
bitplane stack; the read precision is a per-tick, per-layer decision.
At ``kv_bits == B`` the plane-read path must be BIT-identical to the
dense-read parity oracle (same materialization, same attention math),
so every mode / pipelining / speculative configuration of the engine is
checked token-for-token plane vs dense. Below ``B`` the kernel's
interpret twin is checked against the jnp oracle, and the overlay state
must survive the scheduler's slot lifecycle (insert / rollback / reset)
exactly like the dense representation does.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kv_attention import (kv_decode_attention,
                                        materialize_kv_planes)
from repro.models.attention import (decode_attention_planes,
                                    encode_kv_rows, update_kv_planes)
from repro.serving import ServingEngine
from repro.serving.kv_cache import (insert_slot_state, make_decode_state,
                                    make_prefill_state, reset_state,
                                    rollback_decode_state, stage_bytes)

BITS = 8
MODES = ("dynamic", "static:llm_mq", "max", "exact")


# ---------------------------------------------------------------------------
# Representation round trips
# ---------------------------------------------------------------------------
def test_encode_materialize_round_trip():
    """Full-stack materialization reconstructs the written rows to
    within scale/2, and all-zero rows (the speculative-rewind invariant)
    come back EXACTLY zero at every read precision."""
    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(2, 6, 3, 32)), np.float32)
    x[0, 2] = 0.0                                  # a rewound/unwritten row
    planes, scale, zero = encode_kv_rows(jnp.asarray(x), BITS)
    assert planes.shape == (2, BITS, 6, 3, 1) and planes.dtype == jnp.int32
    assert scale.shape == zero.shape == (2, 6, 3, 1)
    for i in range(2):
        full = materialize_kv_planes(planes[i], scale[i], zero[i], BITS,
                                     bits=BITS, d=32)
        np.testing.assert_allclose(np.asarray(full), x[i], atol=0.05)
    for b in (1, 4, BITS):
        low = materialize_kv_planes(planes[0], scale[0], zero[0], b,
                                    bits=BITS, d=32)
        assert not np.asarray(low[2]).any()        # exact zeros at any b


def test_update_kv_planes_writes_only_the_window():
    """An M-row write lands at [pos, pos+M) and touches nothing else."""
    rng = np.random.default_rng(1)
    t, hkv, dh, m, pos = 16, 2, 32, 3, 5
    kp = jnp.zeros((1, BITS, t, hkv, dh // 32), jnp.int32)
    ks = kz = jnp.zeros((1, t, hkv, 1), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(1, m, hkv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(1, m, hkv, dh)), jnp.float32)
    kp, ks, kz, vp, vs, vz = update_kv_planes(
        kp, ks, kz, kp, ks, kz, k_new, v_new, jnp.int32(pos), bits=BITS)
    for planes, s, z, want in ((kp, ks, kz, k_new), (vp, vs, vz, v_new)):
        full = np.asarray(materialize_kv_planes(planes[0], s[0], z[0],
                                                BITS, bits=BITS, d=dh))
        np.testing.assert_allclose(full[pos:pos + m], np.asarray(want[0]),
                                   atol=0.05)
        assert not full[:pos].any() and not full[pos + m:].any()


def test_plane_read_full_bits_is_bit_identical_to_dense_oracle():
    """read="plane" at kv_bits == B must match read="dense" (full-stack
    materialize + shared dense math) bit for bit — the identity every
    engine-level parity claim reduces to."""
    rng = np.random.default_rng(2)
    b, t, hkv, hq, dh = 2, 16, 2, 4, 32
    kv = jnp.asarray(rng.normal(size=(2, b, t, hkv, dh)), jnp.float32)
    kp, ks, kz = encode_kv_rows(kv[0], BITS)
    vp, vs, vz = encode_kv_rows(kv[1], BITS)
    q = jnp.asarray(rng.normal(size=(b, 1, hq, dh)), jnp.float32)
    kw = dict(bits=BITS, logit_softcap=0.0)
    out_p = decode_attention_planes(q, kp, ks, kz, vp, vs, vz,
                                    jnp.int32(11), read="plane",
                                    backend="ref", **kw)
    out_d = decode_attention_planes(q, kp, ks, kz, vp, vs, vz,
                                    jnp.int32(11), read="dense", **kw)
    assert np.array_equal(np.asarray(out_p), np.asarray(out_d))
    # explicit full-B kv_bits is the same claim
    out_b = decode_attention_planes(q, kp, ks, kz, vp, vs, vz,
                                    jnp.int32(11), read="plane",
                                    backend="ref",
                                    kv_bits=jnp.full((b,), BITS), **kw)
    assert np.array_equal(np.asarray(out_b), np.asarray(out_d))


def test_kernel_interpret_matches_oracle_mixed_bits():
    """The Pallas kernel (interpret twin) vs the jnp oracle over a mixed
    per-slot read-precision vector, idle slot included."""
    rng = np.random.default_rng(3)
    s, t, hkv, hq, dh, m = 3, 16, 2, 4, 32, 2
    kv = jnp.asarray(rng.normal(size=(2, s, t, hkv, dh)), jnp.float32)
    kp, ks, kz = encode_kv_rows(kv[0], BITS)
    vp, vs, vz = encode_kv_rows(kv[1], BITS)
    q = jnp.asarray(rng.normal(size=(s, m, hq, dh)), jnp.float32)
    lens = jnp.asarray([[9, 10], [16, 16], [4, 5]], jnp.int32)
    kv_b = jnp.asarray([BITS, 3, 0], jnp.int32)
    args = (q, kp, ks, kz, vp, vs, vz, lens, kv_b)
    out_i = kv_decode_attention(*args, bits=BITS, backend="interpret")
    out_r = kv_decode_attention(*args, bits=BITS, backend="ref")
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                               atol=1e-5)
    assert not np.asarray(out_i[2]).any()          # idle slot: exact zeros
    assert not np.asarray(out_r[2]).any()


def test_kernel_vmap_flattens_onto_slot_axis():
    """vmapping the dispatch (the scheduler's slot vmap) must equal the
    flat slot-batched call — the custom_vmap flattening rule."""
    rng = np.random.default_rng(4)
    o, s, t, hkv, hq, dh = 2, 2, 16, 2, 4, 32
    kv = jnp.asarray(rng.normal(size=(2, o * s, t, hkv, dh)), jnp.float32)
    kp, ks, kz = encode_kv_rows(kv[0], BITS)
    vp, vs, vz = encode_kv_rows(kv[1], BITS)
    q = jnp.asarray(rng.normal(size=(o * s, 1, hq, dh)), jnp.float32)
    lens = jnp.full((o * s, 1), t, jnp.int32)
    kv_b = jnp.asarray([8, 5, 0, 2], jnp.int32)
    flat = kv_decode_attention(q, kp, ks, kz, vp, vs, vz, lens, kv_b,
                               bits=BITS, backend="ref")

    def shaped(a):
        return a.reshape((o, s) + a.shape[1:])

    nested = jax.vmap(lambda *a: kv_decode_attention(*a, bits=BITS,
                                                     backend="ref"))(
        *[shaped(a) for a in (q, kp, ks, kz, vp, vs, vz, lens, kv_b)])
    assert np.array_equal(np.asarray(nested.reshape(flat.shape)),
                          np.asarray(flat))


# ---------------------------------------------------------------------------
# Overlay state lifecycle (slot insert / speculative rewind / recycle)
# ---------------------------------------------------------------------------
def _filled(state, seed):
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in state.items():
        if v.dtype == jnp.int32 and k != "pos":
            out[k] = jnp.asarray(
                rng.integers(-2 ** 30, 2 ** 30, v.shape), jnp.int32)
        elif k == "pos":
            out[k] = v
        else:
            out[k] = jnp.asarray(rng.normal(size=v.shape), v.dtype)
    return out


def test_overlay_state_layout_and_stage_bytes(tiny_bundle):
    cfg = tiny_bundle[0]
    ov = make_decode_state(cfg, 1, 16, dtype=jnp.float32,
                           kv_format="overlay")
    de = make_decode_state(cfg, 1, 16, dtype=jnp.float32)
    plane_keys = [k for k in ov if k.endswith("_planes")]
    assert plane_keys
    for k in plane_keys:
        assert ov[k].shape[1] == BITS and ov[k].dtype == jnp.int32
        pre = k.rsplit(".", 1)[0]
        for suffix in ("k_scale", "k_zero", "v_scale", "v_zero"):
            assert f"{pre}.{suffix}" in ov
    sb_ov, sb_de = stage_bytes(ov), stage_bytes(de)
    for sb in (sb_ov, sb_de):
        assert sb["kv"] == sb["kv_planes"] + sb["kv_scales"] + sb["kv_dense"]
        assert sb["total"] == sb["kv"] + sb["ssm"] + sb["xkv"] + sb["other"]
    assert sb_ov["kv_dense"] == 0 and sb_ov["kv_planes"] > 0
    assert sb_de["kv_planes"] == 0 and sb_de["kv_dense"] > 0


def test_overlay_insert_slot_state_places_kv_block(tiny_bundle):
    """The prefill->decode handoff on the overlay representation: plane
    stacks land at (slot, :, [offset, offset+keep)), scale rows ride
    along, pos rebases — all other slots untouched."""
    cfg = tiny_bundle[0]
    src = _filled(make_prefill_state(cfg, 1, 8, 4, dtype=jnp.float32,
                                     kv_format="overlay"), seed=7)
    src["pos"] = jnp.int32(6)
    proto = make_decode_state(cfg, 1, 16, dtype=jnp.float32,
                              kv_format="overlay")
    dst = {k: jnp.zeros((2,) + v.shape, v.dtype) for k, v in proto.items()}
    out = jax.jit(insert_slot_state)(dst, src, jnp.int32(1), jnp.int32(3))
    assert int(out["pos"][1]) == 9
    for k, v in src.items():
        if k == "pos":
            continue
        got = np.asarray(out[k])
        assert not got[0].any()                    # slot 0 untouched
        if k.endswith("_planes"):
            keep = min(v.shape[2], got.shape[3] - 3)
            np.testing.assert_array_equal(got[1, 0, :, 3:3 + keep],
                                          np.asarray(v)[0, :, :keep])
            assert not got[1, 0, :, :3].any()
        elif k.startswith("kv."):
            keep = min(v.shape[1], got.shape[2] - 3)
            np.testing.assert_array_equal(got[1, 0, 3:3 + keep],
                                          np.asarray(v)[0, :keep])
            assert not got[1, 0, :3].any()
        else:
            np.testing.assert_array_equal(got[1], np.asarray(v))


def test_overlay_rollback_zeroes_rejected_rows(tiny_bundle):
    """Speculative rewind on the overlay state: rows in
    [new_pos, new_pos + window) are zeroed across ALL planes and the
    scale/zero rows, earlier rows are untouched, pos rebases."""
    cfg = tiny_bundle[0]
    window, n_keep = 4, 2
    state = _filled(make_decode_state(cfg, 1, 16, dtype=jnp.float32,
                                      kv_format="overlay"), seed=8)
    state["pos"] = jnp.int32(10)                   # post-verify position
    out = jax.jit(rollback_decode_state, static_argnames="window")(
        state, {}, jnp.int32(n_keep), window)
    new_pos = 10 - window + n_keep
    assert int(out["pos"]) == new_pos
    for k, v in state.items():
        if not k.startswith("kv."):
            continue
        got, before = np.asarray(out[k]), np.asarray(v)
        axis = 2 if k.endswith("_planes") else 1
        sl = [slice(None)] * got.ndim
        sl[axis] = slice(new_pos, new_pos + window)
        assert not got[tuple(sl)].any(), k
        sl[axis] = slice(0, new_pos)
        np.testing.assert_array_equal(got[tuple(sl)],
                                      before[tuple(sl)], err_msg=k)


def test_overlay_reset_state_zero_fills(tiny_bundle):
    cfg = tiny_bundle[0]
    state = _filled(make_decode_state(cfg, 1, 8, dtype=jnp.float32,
                                      kv_format="overlay"), seed=9)
    out = reset_state(state)
    assert set(out) == set(state)
    for k, v in out.items():
        assert not np.asarray(v).any(), k


# ---------------------------------------------------------------------------
# Engine-level identity matrix: plane read vs dense-read parity oracle
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module", params=[True, False], ids=["async", "sync"])
def engines(request, tiny_bundle):
    """(plane-read, dense-read) overlay engines, kv_dynamic=False — the
    bit-identity configuration (every read at the full plane stack)."""
    cfg, params, model, _ = tiny_bundle
    plane = ServingEngine(cfg, params, model, use_async=request.param,
                          kv_overlay=True, kv_dynamic=False)
    dense = ServingEngine(cfg, params, model, use_async=request.param,
                          kv_overlay=True, kv_dynamic=False,
                          kv_read="dense")
    return plane, dense


@pytest.mark.parametrize("mode", MODES)
def test_engine_plane_vs_dense_identity(engines, tiny_bundle, mode):
    """Every serving mode, async and sync pipelining: full-stack plane
    reads produce the SAME tokens as the dense-read oracle."""
    cfg = tiny_bundle[0]
    plane, dense = engines
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32)
    out_p, eb_p = plane.generate(prompt, 3, 3.5, mode=mode)
    out_d, eb_d = dense.generate(prompt, 3, 3.5, mode=mode)
    assert np.array_equal(out_p, out_d), mode
    np.testing.assert_allclose(eb_p, eb_d, atol=1e-6)


def test_engine_identity_across_prefill_handoff(engines, tiny_bundle):
    """A prompt crossing the prefill chunk boundary (19 > 16): the
    chunked prefill writes + handoff on the overlay cache keep parity."""
    cfg = tiny_bundle[0]
    plane, dense = engines
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab_size, (1, 19)).astype(np.int32)
    out_p, eb_p = plane.generate(prompt, 3, 4.0)
    out_d, eb_d = dense.generate(prompt, 3, 4.0)
    assert np.array_equal(out_p, out_d)
    np.testing.assert_allclose(eb_p, eb_d, atol=1e-6)


def test_engine_speculative_identity(engines, tiny_bundle):
    """spec_k on the overlay cache: the plane engine's speculative run
    equals its own non-speculative run (greedy verify identity, which
    exercises the overlay rollback) AND the dense-read speculative run."""
    cfg = tiny_bundle[0]
    plane, dense = engines
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32)
    out_base, eb_base = plane.generate(prompt, 4, 4.0)
    out_spec, eb_spec = plane.generate(prompt, 4, 4.0, spec_k=2)
    assert np.array_equal(out_spec, out_base)
    np.testing.assert_allclose(eb_spec, eb_base, atol=1e-6)
    out_dspec, _ = dense.generate(prompt, 4, 4.0, spec_k=2)
    assert np.array_equal(out_spec, out_dspec)


def test_scheduler_overlay_parity(engines, tiny_bundle):
    """The slot scheduler over overlay engines: continuous batching with
    plane reads (vmapped kernel dispatch, overlay insert handoff,
    speculative slot rollback) matches the dense-read oracle."""
    from repro.serving import LatencyModel, QoSPlanner, Request, \
        SlotScheduler

    cfg, _, model, _ = tiny_bundle
    plane, dense = engines
    rng = np.random.default_rng(14)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (3 + i,)).astype(np.int32),
                    max_new=3, tpot_budget_s=6e-3)
            for i in range(2)]

    def run(engine):
        qos = QoSPlanner(sorted(model.adaptations),
                         LatencyModel(bytes_per_bit=1e9), chips=1)
        sched = SlotScheduler(engine, qos, slots=2, max_prompt=8,
                              max_new=3, chunk=4, spec_k=2)
        fresh = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                         tpot_budget_s=r.tpot_budget_s) for r in reqs]
        return {r.rid: r for r in sched.run(fresh)}

    done_p, done_d = run(plane), run(dense)
    assert len(done_p) == len(reqs)
    for rid in done_p:
        assert np.array_equal(done_p[rid].tokens, done_d[rid].tokens)
        np.testing.assert_allclose(done_p[rid].effective_bits,
                                   done_d[rid].effective_bits, atol=1e-6)


# ---------------------------------------------------------------------------
# Dynamic KV bits: planner carry, one launch, byte accounting
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dyn_engine(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return ServingEngine(cfg, params, model, kv_overlay=True)


def test_dynamic_kv_engine_generates(dyn_engine, tiny_bundle):
    """Planner-assigned per-layer KV read bits end to end: the bundle
    carries one KV pseudo-row per attention layer, generation runs, and
    the overlay actually shrinks the KV footprint."""
    cfg = tiny_bundle[0]
    bundle = dyn_engine.artifacts.decision
    assert bundle.weight_units < bundle.n_units
    assert len(bundle.kv_rows) == sum(
        1 for p in bundle.paths if p.endswith(".attn.kv"))
    rng = np.random.default_rng(15)
    prompt = rng.integers(0, cfg.vocab_size, (1, 4)).astype(np.int32)
    out, ebits = dyn_engine.generate(prompt, 4, 3.5)
    assert out.shape == (1, 8)
    assert np.all(np.isfinite(ebits))
    assert all(0.0 < e <= 8.0 for e in ebits)
    assert dyn_engine.kv_bytes_saved(1, 128) > 0


def test_kv_bytes_saved_zero_without_overlay(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    assert ServingEngine(cfg, params, model).kv_bytes_saved(1, 128) == 0


def test_one_planner_launch_per_planned_tick(dyn_engine, monkeypatch):
    """KV read bits must ride the SAME fused plan_bits launch as the
    weight bits — tracing one planned tick hits the planner exactly
    once."""
    import repro.core.decision as decision_mod

    calls = []
    orig = decision_mod.plan_bits

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(decision_mod, "plan_bits", counting)
    tick = dyn_engine.build_planned_tick("dynamic")
    state = dyn_engine._make_state(1, 32)
    tokens = jnp.zeros((1, 1), jnp.int32)
    planned = jnp.full((dyn_engine.artifacts.decision.n_units,), 4,
                       jnp.int32)
    jax.eval_shape(tick, state, tokens, jnp.int32(0), planned)
    assert len(calls) == 1
