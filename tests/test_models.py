"""Per-architecture smoke tests (reduced configs) + numeric cross-checks.

Every ASSIGNED architecture instantiates a reduced same-family config and
runs one forward + one train step on CPU, asserting output shapes and the
absence of NaNs (assignment spec). The FULL configs are exercised only via
the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import (decode_step, forward, init_decode_state,
                          init_model_params, linear_units, loss_fn)
from repro.models.frontends import frontend_input_name, stub_frontend_embeddings


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_arch_smoke(arch):
    cfg = get_config(arch, reduced_=True)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    kw = {}
    fin = frontend_input_name(cfg)
    if fin:
        kw[fin] = stub_frontend_embeddings(cfg, B)
    logits, aux = forward(cfg, params, toks, q_chunk=16, kv_chunk=16, **kw)
    extra = cfg.frontend_tokens if fin == "prefix_embeds" else 0
    assert logits.shape == (B, S + extra, cfg.padded_vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    # one train step decreases nothing catastrophically (finite loss+grads)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    lf = lambda p: loss_fn(cfg, p, toks, labels, q_chunk=16, kv_chunk=16,
                           **({"frames": kw.get("frames"),
                               "prefix_embeds": kw.get("prefix_embeds")}))
    loss, grads = jax.value_and_grad(lf)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in grads.values())
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-370m", "dbrx-132b",
                                  "jamba-1.5-large-398b", "whisper-base"])
def test_reduced_arch_decode(arch):
    cfg = get_config(arch, reduced_=True)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    st = init_decode_state(cfg, 2, 16, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    lg, st = decode_step(cfg, params, st, tok)
    lg2, st = decode_step(cfg, params, st, tok)
    assert lg.shape == (2, 1, cfg.padded_vocab_size)
    assert not np.any(np.isnan(np.asarray(lg2, np.float32)))
    assert int(st["pos"]) == 2


def test_decode_matches_forward_teacher_forced():
    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                              cfg.vocab_size)
    full, _ = forward(cfg, params, toks)
    st = init_decode_state(cfg, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, st = decode_step(cfg, params, st, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=2e-3,
                               atol=2e-3)


def test_linear_units_census():
    # llama-family: 7 units per block (paper: 224 for 32 layers)
    cfg = get_config("llama3-8b")
    units = linear_units(cfg)
    assert len(units) == 32 * 7
    async_units = [u for u in units if u.async_eligible]
    assert len(async_units) == 32 * 5          # q,k,v,gate,up
    # ssm arch: 2 units per block
    assert len(linear_units(get_config("mamba2-370m"))) == 48 * 2


def test_flash_attention_gqa_vs_naive():
    from repro.models.attention import flash_attention
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 8, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 2, 16))
    o = flash_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    kr, vr = jnp.repeat(k, 4, 2), jnp.repeat(v, 4, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / 4.0
    s = jnp.where(jnp.tril(jnp.ones((32, 32), bool)), s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(o, ref, rtol=2e-4, atol=2e-5)


def test_int8_kv_cache_decode_close_to_fp():
    """Beyond-paper §Perf optimization: int8 KV halves decode memory at
    ~1% relative logit error."""
    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                              cfg.vocab_size)
    full, _ = forward(cfg, params, toks)
    st = init_decode_state(cfg, 2, 16, dtype=jnp.float32,
                           kv_dtype=jnp.int8)
    outs = []
    for t in range(12):
        lg, st = decode_step(cfg, params, st, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.mean(jnp.abs(dec - full)) / jnp.mean(jnp.abs(full)))
    assert rel < 0.03, rel
    assert st[f"kv.0.k"].dtype == jnp.int8


def test_ssm_decode_matches_chunked_forward():
    cfg = get_config("tiny-ssm")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 12), 0,
                              cfg.vocab_size)
    full, _ = forward(cfg, params, toks)
    st = init_decode_state(cfg, 1, 16, dtype=jnp.float32)
    outs = []
    for t in range(12):
        lg, st = decode_step(cfg, params, st, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=1e-3,
                               atol=1e-3)
